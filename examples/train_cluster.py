"""End-to-end driver: train the ~100M `repro-100m` model for a few hundred
steps, submitted through the SLURM layer exactly like the guide's §5.2.4
job script — with checkpoints, resume, and Prometheus metrics.

Run:  PYTHONPATH=src python examples/train_cluster.py [--steps 300]
      (CPU: ~100M params; expect a few hundred ms per 8x128-token step.)
"""
import argparse

from repro.cluster import commands, provision, tpu_pod_spec
from repro.cluster.meshbridge import mesh_for_job
from repro.configs import RunConfig, get_config
from repro.configs.base import InputShape
from repro.monitoring import MetricsRegistry
from repro.optim import OptimizerConfig
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cluster = provision(tpu_pod_spec(hosts_x=4, hosts_y=4), real_mode=True)
    metrics = MetricsRegistry()
    cluster.metrics = metrics

    cfg = get_config("repro-100m")
    print(f"model: {cfg.name}  params={cfg.param_count():,}")

    def train_script(job, alloc):
        mesh = mesh_for_job(cluster, job)
        trainer = Trainer(
            cfg,
            RunConfig(strategy="fsdp_tp", microbatches=1, remat="layer"),
            mesh,
            InputShape("train", args.seq, args.batch, "train"),
            OptimizerConfig(peak_lr=3e-4, warmup_steps=20,
                            decay_steps=args.steps),
            TrainerConfig(steps=args.steps, log_every=10, ckpt_every=100,
                          ckpt_dir=args.ckpt_dir),
            metrics=metrics)
        history = trainer.train()
        return history

    msg = commands.sbatch(cluster, name="train_repro_100m", nodes=16,
                          gres="tpu:4", mem="32G", time="24:00:00",
                          script=train_script, run_time_s=3600)
    print(msg)
    job = cluster.jobs[int(msg.split()[-1])]
    if job.exit_code != 0:
        raise SystemExit(f"job failed: {job.comment}")
    history = job.result
    first, last = history[0], history[-1]
    print(f"\nloss: {first['loss']:.4f} (step {first['step']}) -> "
          f"{last['loss']:.4f} (step {last['step']})")
    cluster.run()
    print(commands.sacct(cluster))


if __name__ == "__main__":
    main()
