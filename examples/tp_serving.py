"""Tensor-parallel serving on forced host devices: shard the model and
the paged KV pool over a 2-way ``model`` mesh, then decode the same
workload at TP=1 and TP=2 and check the greedy outputs match
token-for-token (the engine reduces in float32, so an f32 model is
bit-identical at any TP degree — see README "Tensor-parallel serving").

Run:  PYTHONPATH=src python examples/tp_serving.py [--tp 2]

No GPUs needed: the CPU backend is told to expose ``--tp`` devices
before jax is imported, so the shard_map collectives are real.
"""
import argparse
import dataclasses
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--tp", type=int, default=2)
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--max-new", type=int, default=16)
args = ap.parse_args()

# must happen before `import jax` anywhere in the process
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           f" --xla_force_host_platform_device_count="
                           f"{args.tp}")

import numpy as np  # noqa: E402

from repro.configs import get_reduced_config  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.serving import DecodeEngine, Request  # noqa: E402


def serve(mesh, cfg, params):
    engine = DecodeEngine(cfg, params, num_slots=4, cache_len=128,
                          decode_chunk=4, kv_page_size=16, mesh=mesh)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(8, 32))).astype(
                                            np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    while engine.step() > 0 or engine.queue:
        pass
    return engine, reqs, time.perf_counter() - t0


def main():
    # f32 so TP=1 and TP=N decode bit-identically (bf16 keeps ~1-ulp
    # logit noise from the reassociated psum)
    cfg = dataclasses.replace(get_reduced_config("stablelm-3b"),
                              dtype="float32")
    params = init_params(cfg, 0)

    _, base, base_dt = serve(None, cfg, params)
    engine, reqs, tp_dt = serve(make_mesh(1, args.tp), cfg, params)

    st = engine.tp_stats()
    ps = st["psums_per_token"]
    print(f"plan: {st['plan']}")
    print(f"devices: {', '.join(st['devices'])}")
    print(f"psums/token: {sum(ps.values())} "
          f"(attn_out {ps['attn_out']}, mlp_out {ps['mlp_out']})")
    for k, n in enumerate(st.get("kv_pages_in_use", [])):
        print(f"KV pool shard {k}: {n}/{st['kv_pages_total']} pages "
              f"in use (each holds 1/{st['tp']} of every page's heads)")
    for note in st["notices"]:
        print(f"notice: {note}")

    toks = sum(len(r.output) for r in reqs)
    print(f"{args.requests} requests, {toks} tokens: "
          f"tp=1 {base_dt:.1f}s, tp={args.tp} {tp_dt:.1f}s")
    same = all(b.output == r.output for b, r in zip(base, reqs))
    print(f"greedy outputs identical across TP degrees: {same}")
    assert same, "f32 TP decode must match TP=1 token-for-token"


if __name__ == "__main__":
    main()
