"""Batched serving with continuous batching: submit a stream of requests,
decode them through shared KV-cache slots, report throughput + latency
quantiles (the serving-engine role that TensorRT plays in the guide's GPU
world — see DESIGN.md assumption log #5).

Run:  PYTHONPATH=src python examples/serve_batch.py [--requests 16]
"""
import argparse
import time

import numpy as np

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.monitoring import MetricsRegistry
from repro.serving import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    metrics = MetricsRegistry()
    engine = DecodeEngine(cfg, params, num_slots=args.slots,
                          cache_len=256, metrics=metrics)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(8, 48))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, plen).astype(
                np.int32),
            max_new_tokens=args.max_new,
            temperature=0.0 if i % 2 == 0 else 0.8))
        engine.submit(reqs[-1])

    t0 = time.perf_counter()
    n_steps = 0
    while engine.step() > 0 or engine.queue:
        n_steps += 1
    dt = time.perf_counter() - t0

    toks = int(metrics.counter("serve_tokens_generated").value())
    print(f"{args.requests} requests through {args.slots} slots: "
          f"{toks} tokens in {dt:.1f}s -> {toks / dt:,.1f} tok/s "
          f"({n_steps} batched steps)")
    print(f"decode p50 "
          f"{metrics.histogram('serve_decode_seconds').quantile(0.5)*1e3:.0f}"
          f"ms  p90 "
          f"{metrics.histogram('serve_decode_seconds').quantile(0.9)*1e3:.0f}"
          f"ms")
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.output[:8]}...")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
