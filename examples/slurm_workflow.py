"""SLURM workflow tour: everything §5 of the guide demonstrates, live —
priorities, EASY backfill, dependencies (afterok/afternotok), job arrays,
node drain + requeue, and HA controller failover.

Run:  PYTHONPATH=src python examples/slurm_workflow.py
"""
from repro.cluster import (
    Cluster, JobState, NodeState, ResourceRequest, commands, provision,
    tpu_pod_spec,
)


def req(nodes=1, time_s=3600):
    return ResourceRequest(nodes=nodes, gres_per_node={"tpu": 4},
                           time_limit_s=time_s)


def main():
    cluster = provision(tpu_pod_spec(hosts_x=4, hosts_y=2))   # 8 hosts

    print("== backfill (§3.2.3) ==")
    (long_,) = cluster.submit("long-train", req(nodes=4), run_time_s=3600)
    (head,) = cluster.submit("big-eval", req(nodes=8), priority=9,
                             run_time_s=600)
    (short,) = cluster.submit("short-probe", req(nodes=2, time_s=1800),
                              run_time_s=1200)
    print(commands.squeue(cluster))
    print(f"head of queue blocked -> reservation; short job backfilled: "
          f"{cluster.jobs[short].state.name}\n")

    print("== dependencies (§5.2) ==")
    (prep,) = cluster.submit("preprocess", req(), run_time_s=60)
    (train,) = cluster.submit("train", req(), dependency=f"afterok:{prep}",
                              run_time_s=120)
    (rescue,) = cluster.submit("rescue", req(),
                               dependency=f"afternotok:{train}",
                               run_time_s=30)
    print(commands.squeue(cluster))

    print("== job array (hyperparameter sweep) ==")
    arr = cluster.submit("sweep-lr", req(), array=4, run_time_s=300)
    print(f"submitted array {arr}\n")

    print("== drain + requeue (§6.3 maintenance) ==")
    victim = cluster.jobs[long_].nodes_alloc[0]
    commands.scontrol_update_node(cluster, victim, "down", reason="ECC")
    print(f"node {victim} down -> long-train is "
          f"{cluster.jobs[long_].state.name} "
          f"(reason={cluster.jobs[long_].reason!r})")
    cluster.set_node_state(victim, NodeState.IDLE)
    print(f"node restored -> long-train is "
          f"{cluster.jobs[long_].state.name}\n")

    print("== HA failover (§4 slurm_enable_ha) ==")
    snap = cluster.snapshot()
    standby = Cluster.restore(snap)
    standby.run()
    done = sum(1 for j in standby.jobs.values()
               if j.state == JobState.COMPLETED)
    print(f"standby controller drained the queue: {done}/"
          f"{len(standby.jobs)} completed\n")

    print("== sacct (accounting, §6.1) ==")
    print(commands.sacct(standby))


if __name__ == "__main__":
    main()
