"""Elastic serving tour: batch and serving contend for one cluster.

The elastic tier in one sitting — serving replicas are *scavenger jobs*
inside the SLURM simulation, so batch training and interactive decode
negotiate nodes through the cluster's own policy machinery:

* **scale up** — the :class:`~repro.serving.Autoscaler` probes
  ``Cluster.capacity_now`` ("largest replica-shaped job that starts
  immediately", slurm_now-style) and grows the
  :class:`~repro.serving.Router`'s fleet into idle nodes, one
  ``kind="serve_replica"`` scavenger placeholder job per replica;
* **prefix affinity** — the router consistent-hashes each request's
  first prompt page (SHA-1 ring, 64 vnodes/replica), so everyone
  sharing a system prompt lands on the replica whose radix prefix
  cache already holds those pages;
* **contention** — a high-QOS training job preempts one placeholder
  through the cluster's QOS machinery; the next autoscaler tick drains
  that replica: in-flight requests are evicted with partial output
  retained, re-routed through the surviving ring, and finish with
  greedy outputs bit-identical to an undisturbed run;
* **scale back up** — training ends, the probe sees idle nodes again,
  and the fleet regrows.

``sdiag`` prints the router and autoscaler sections after each act.
The same flow is available from the CLI:

    PYTHONPATH=src python -m repro.launch.serve \
        --replicas 2 --affinity --autoscale --prefix-cache

Run:  PYTHONPATH=src python examples/elastic_serving.py
"""
import numpy as np

from repro.cluster import ResourceRequest, commands, provision, tpu_pod_spec
from repro.configs import get_reduced_config
from repro.models import init_params
from repro.monitoring import MetricsRegistry
from repro.serving import Autoscaler, DecodeEngine, Request, Router


def main():
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    metrics = MetricsRegistry()

    # -- the cluster: 4 hosts, serving will scavenge whatever is idle --
    cluster = provision(tpu_pod_spec(hosts_x=4, hosts_y=1))

    def make_engine(admission):
        return DecodeEngine(cfg, params, num_slots=2, cache_len=128,
                            metrics=metrics, admission=admission,
                            decode_chunk=4, kv_page_size=16,
                            prefix_cache=True)

    router = Router(make_engine, replicas=0, policy="affinity",
                    metrics=metrics)
    router.add_tenant("chat", shares=4)
    scaler = Autoscaler(
        router, cluster,
        req=ResourceRequest(nodes=1, gres_per_node={"tpu": 4},
                            time_limit_s=36_000),
        min_replicas=1, max_replicas=3)

    print("== act 1: the autoscaler scavenges the idle pod ==")
    scaler.tick()
    print(f"replicas: {sorted(router.replicas)}  "
          f"(probe saw {scaler.stats['last_probe']} idle node(s))")
    print(commands.squeue(cluster), "\n")

    # -- two user populations, each behind a shared system prompt --
    rng = np.random.default_rng(0)
    sys_a = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)
    sys_b = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)

    def chat(rid, system):
        tail = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        return Request(rid=rid, prompt=np.concatenate([system, tail]),
                       max_new_tokens=8, tenant="chat")

    print("== act 2: shared-prefix traffic routes by affinity ==")
    reqs = [chat(i, sys_a if i % 2 == 0 else sys_b) for i in range(8)]
    placed = [router.submit(r) for r in reqs]
    print(f"placement (A=even, B=odd rids): {placed}")
    router.step()                              # some partial output
    print(f"affinity hits: {router.stats['affinity_hits']}/"
          f"{router.stats['routed']}\n")

    print("== act 3: high-QOS training takes nodes back ==")
    cluster.submit("train-ft", ResourceRequest(
        nodes=3, gres_per_node={"tpu": 4}, time_limit_s=7200),
        user="alice", qos="high", run_time_s=600)
    scaler.tick()                              # reaps the lost placeholder
    print(f"preemptions: {cluster.preemptions_total}; "
          f"replicas now: {sorted(router.replicas)}; "
          f"{scaler.stats['requeued_requests']} in-flight request(s) "
          f"re-routed with partial output retained")
    router.run_to_completion()
    done = sum(r.done for r in reqs)
    moved = [r.rid for r in reqs if r.preemptions]
    print(f"finished {done}/{len(reqs)}; drained mid-decode: {moved} "
          f"(outputs bit-identical to an undisturbed run)\n")

    print(commands.sdiag(cluster=cluster, router=router,
                         autoscaler=scaler), "\n")

    print("== act 4: training ends, the fleet regrows ==")
    cluster.run()                              # drive batch to completion
    scaler.tick()
    print(f"replicas: {sorted(router.replicas)}  "
          f"(scale-ups total: {scaler.stats['scale_ups']})")


if __name__ == "__main__":
    main()
