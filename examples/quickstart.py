"""Quickstart: the guide's end-to-end loop in one minute.

Provision a software-defined TPU cluster (the §4 DeepOps flow), validate it
(§4 step 8), submit a real training job with `sbatch` (§5.2.3), watch it
with `squeue`/`sinfo`, and read the accounting with `sacct` (§6).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.cluster import commands, provision, tpu_pod_spec, validate
from repro.cluster.meshbridge import mesh_for_job
from repro.configs import RunConfig, get_reduced_config
from repro.configs.base import InputShape
from repro.monitoring import MetricsRegistry
from repro.optim import OptimizerConfig
from repro.training.trainer import Trainer, TrainerConfig


def main():
    # ---- provision + validate (paper §4) --------------------------------
    spec = tpu_pod_spec(name="v5e-demo", hosts_x=4, hosts_y=4)   # 64 chips
    cluster = provision(spec, real_mode=True)
    report = validate(cluster, spec)
    print("== slurm-validation ==")
    print(report, "\n")

    print("== sinfo ==")
    print(commands.sinfo(cluster), "\n")

    # ---- the deep_learning_job of §5.2.4 --------------------------------
    metrics = MetricsRegistry()

    def train_script(job, alloc):
        cfg = get_reduced_config("stablelm-3b")
        mesh = mesh_for_job(cluster, job)
        trainer = Trainer(
            cfg, RunConfig(strategy="dp", remat="none"), mesh,
            InputShape("demo", 64, 4, "train"),
            OptimizerConfig(peak_lr=1e-3, warmup_steps=5, decay_steps=100),
            TrainerConfig(steps=20, log_every=5), metrics=metrics)
        return trainer.train()

    msg = commands.sbatch(
        cluster, name="deep_learning_job", nodes=4, gres="tpu:4",
        cpus_per_task=8, mem="32G", time="24:00:00", script=train_script)
    print("== sbatch ==")
    print(msg, "\n")

    print("== squeue ==")
    print(commands.squeue(cluster), "\n")

    cluster.run()

    print("\n== sacct ==")
    print(commands.sacct(cluster), "\n")

    print("== metrics (ascii grafana, §6) ==")
    print(metrics.dashboard())


if __name__ == "__main__":
    main()
