"""Pipeline parallelism demo (paper §7.1 "PipelineParallel"): a 2-stage
GPipe-style microbatch schedule over `lax.ppermute`, trained end-to-end, and
checked against the sequential run.

This script forces 2 host devices (must be set before jax imports), so run
it as its own process:

    PYTHONPATH=src python examples/pipeline_parallel.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import make_pipeline_mesh, pipeline_apply


def main():
    n_stages, n_micro = 2, 8
    L, d, mb = 8, 64, 4                       # 8 layers -> 4 per stage
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((L, d, d)) * 0.2, jnp.float32)
    x = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)
    tgt = jnp.asarray(rng.standard_normal((n_micro, mb, d)), jnp.float32)

    def stage_fn(params, h):
        for i in range(params.shape[0]):
            h = jnp.tanh(h @ params[i])
        return h

    mesh = make_pipeline_mesh(n_stages)
    print(f"pipeline mesh: {mesh.shape}  microbatches={n_micro}")

    y = pipeline_apply(stage_fn, w, x, mesh)
    ref = jnp.stack([stage_fn(w, x[i]) for i in range(n_micro)])
    err = float(jnp.max(jnp.abs(y - ref)))
    print(f"forward max |pipeline - sequential| = {err:.2e}")
    assert err < 1e-5

    # train THROUGH the pipeline (it's differentiable end to end)
    def loss(w):
        out = pipeline_apply(stage_fn, w, x, mesh)
        return jnp.mean((out - tgt) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss))    # compile once
    lval, g = grad_fn(w)
    print(f"loss={lval:.4f}  grad_norm="
          f"{float(jnp.linalg.norm(g.reshape(-1))):.4f}")
    for step in range(10):
        lval, g = grad_fn(w)
        w = w - 0.05 * g
    print(f"after 10 steps: loss={float(loss(w)):.4f} (decreasing)")


if __name__ == "__main__":
    main()
