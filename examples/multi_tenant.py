"""Multi-tenant fair-share tour: two tenants share one TPU partition.

Demonstrates the full policy layer the paper's §3.2.3 "fairness policies"
line points at:

* ``sacctmgr`` account tree — ``prod`` (10 shares) vs ``research`` (1 share);
* QOS tiers — prod submits ``high``, research scavenges idle capacity with
  ``scavenger`` (which charges only 25% usage but is first to be evicted);
* preemption — a high job evicts the scavenger sweep; the victim requeues
  and, because it checkpoints every 300s (``ckpt_interval_s``), resumes
  from its last step instead of restarting;
* fair-share convergence — after prod burns TRES-seconds its fair-share
  factor 2^(-usage/shares) drops, so research's queued work rises in
  priority (``sshare`` / ``sprio`` make this visible).

Run:  PYTHONPATH=src python examples/multi_tenant.py
"""
from repro.cluster import ResourceRequest, commands, provision, tpu_pod_spec


def req(nodes, time_s=14_400):
    return ResourceRequest(nodes=nodes, gres_per_node={"tpu": 4},
                           time_limit_s=time_s)


def main():
    cluster = provision(tpu_pod_spec(hosts_x=4, hosts_y=2))   # 8 hosts

    print("== sacctmgr: tenants and shares ==")
    print(commands.sacctmgr_add_account(cluster, "prod", fairshare=10))
    print(commands.sacctmgr_add_account(cluster, "research", fairshare=1))
    commands.sacctmgr_add_user(cluster, "alice", "prod")
    commands.sacctmgr_add_user(cluster, "bob", "research")
    print(commands.sacctmgr_show_assoc(cluster), "\n")
    print(commands.sacctmgr_show_qos(cluster), "\n")

    print("== research scavenges the idle pod ==")
    (sweep,) = cluster.submit("scavenge-sweep", req(nodes=8), user="bob",
                              qos="scavenger", run_time_s=7200,
                              ckpt_interval_s=300)
    print(commands.squeue(cluster), "\n")

    # let the sweep run 20 minutes before production shows up
    cluster.clock += 1200.0

    print("== prod's high-QOS train preempts the scavenger ==")
    (train,) = cluster.submit("prod-train", req(nodes=8), user="alice",
                              qos="high", run_time_s=3600)
    sj = cluster.jobs[sweep]
    print(f"prod-train: {cluster.jobs[train].state.name};  "
          f"sweep: {sj.state.name} (requeued x{sj.requeue_count}, "
          f"kept {sj.progress_s:.0f}s of checkpointed work)\n")

    print("== sprio while the sweep waits ==")
    print(commands.sprio(cluster), "\n")

    cluster.run()

    print("== sacct: both segments of the preempted sweep ==")
    print(commands.sacct(cluster), "\n")

    print("== sshare: usage charged, factors diverged ==")
    print(commands.sshare(cluster))
    print(f"\npreemptions: {cluster.preemptions_total}; "
          f"sweep finished at t={cluster.jobs[sweep].end_time:.0f}s "
          f"(saved {cluster.jobs[sweep].progress_s:.0f}s by resuming "
          f"from checkpoint)")


if __name__ == "__main__":
    main()
