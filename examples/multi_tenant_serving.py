"""Multi-tenant serving tour: one decode engine, two tenants, one ledger.

The serving twin of ``examples/multi_tenant.py``: the same ``repro.policy``
fair-share machinery that orders the batch queue now drives request
admission in the continuous-batching engine:

* tenants are accounts — ``prod`` (8 shares) vs ``research`` (1 share) in
  one :class:`~repro.policy.FairShareTree`;
* every admitted slot is picked by the ``2^(-usage/shares)`` multifactor
  priority, and every generated token / resident KV-cache line charges the
  tenant's account — so sustained load converges to the share ratio;
* research rides the ``scavenger`` QOS: discounted billing, but a blocked
  ``high`` request from prod evicts one of its slots; the victim requeues
  with its partial output retained and resumes where it stopped.

The engine runs the device-resident fast path (``decode_chunk=4``): each
``engine.step()`` below generates FOUR tokens per slot in one jitted
dispatch, with sampling and stop handling fused on device.  Tenancy
semantics are unchanged — admission, ledger charges (batched per chunk),
and QOS preemption happen at chunk boundaries, so the blocked ``high``
request below waits at most one chunk before evicting its victim.  See
README "Serving fast path" for decode-chunk semantics and the prefill
bucket table.

A :class:`~repro.monitoring.Tracer` rides along (README
"Observability"): every request's SUBMIT/QUEUED/PREFILL/DECODE/PREEMPT/
RESUME/FINISH lifecycle lands as spans — the preemption below shows up
as TWO decode segments on the victim's lane — and the derived SLO
histograms power the per-tenant TTFT/ITL report printed at the end.
Pass a path to ``tracer.export_chrome(...)`` to inspect the timeline in
ui.perfetto.dev; ``--trace`` on ``repro.launch.serve`` does the same.

Run:  PYTHONPATH=src python examples/multi_tenant_serving.py
"""
import numpy as np

from repro.configs import get_reduced_config
from repro.models import init_params
from repro.monitoring import MetricsRegistry, Tracer
from repro.monitoring.metrics import (
    METRIC_SERVE_PREEMPTIONS, METRIC_SERVE_TENANT_TOKENS,
)
from repro.serving import AdmissionController, DecodeEngine, Request


def main():
    cfg = get_reduced_config("stablelm-3b")
    params = init_params(cfg, 0)
    metrics = MetricsRegistry()
    tracer = Tracer(metrics=metrics)           # opt-in lifecycle tracing

    print("== tenants: prod (8 shares) vs research (1 share) ==")
    admission = AdmissionController(tracer=tracer)
    admission.add_tenant("prod", shares=8)
    admission.add_tenant("research", shares=1)
    engine = DecodeEngine(cfg, params, num_slots=2, cache_len=128,
                          metrics=metrics, admission=admission,
                          decode_chunk=4, prefill_buckets="auto",
                          tracer=tracer)

    rng = np.random.default_rng(0)

    def req(rid, tenant, qos="normal", max_new=8):
        return Request(rid=rid,
                       prompt=rng.integers(0, cfg.vocab_size, 12).astype(
                           np.int32),
                       max_new_tokens=max_new, tenant=tenant, qos=qos)

    print("== research scavenges both slots while prod is idle ==")
    sweeps = [req(i, "research", qos="scavenger", max_new=48)
              for i in range(2)]
    for r in sweeps:
        engine.submit(r)
    for _ in range(6):
        engine.step()
    print(f"scavenger progress: "
          f"{[len(r.output) for r in sweeps]} tokens decoded\n")

    print("== a blocked high-QOS prod request preempts one slot ==")
    urgent = req(10, "prod", qos="high", max_new=8)
    engine.submit(urgent)
    engine.step()
    victim = next(r for r in sweeps if r.preemptions)
    print(f"evictions: "
          f"{metrics.counter(METRIC_SERVE_PREEMPTIONS).value():.0f}  "
          f"(victim rid={victim.rid} keeps {len(victim.output)} tokens)\n")


    engine.run_to_completion()                 # drain the sweeps
    assert urgent.done and all(r.done for r in sweeps)
    segs = tracer.spans(name="DECODE",
                        track=("serving:research", f"req {victim.rid}"))
    print(f"victim's trace: {len(segs)} decode segments "
          f"(preempt -> resume split on one request lane)\n")

    print("== sustained load converges toward the 8:1 share ratio ==")
    tok = metrics.counter(METRIC_SERVE_TENANT_TOKENS)
    base = {t: tok.value(tenant=t) for t in ("prod", "research")}
    rid = 20
    for _ in range(250):
        for tenant in ("prod", "research"):
            while admission.queued(tenant) < 3:
                engine.submit(req(rid, tenant, max_new=4))
                rid += 1
        engine.step()

    prod_t = tok.value(tenant="prod") - base["prod"]
    res_t = tok.value(tenant="research") - base["research"]
    print(f"tokens this window: prod={prod_t:.0f} research={res_t:.0f} "
          f"(ratio {prod_t / max(res_t, 1):.1f}:1 — research entered the "
          f"window over-served from scavenging, so fair-share claws back "
          f"above 8:1 before settling)")
    engine.run_to_completion()                 # drain the tail quietly
    print("\n== the shared ledger (what sshare would report) ==")
    for name in ("prod", "research"):
        print(f"{name:<10} usage={admission.tree.usage[name]:10.1f} "
              f"fairshare={admission.tree.fair_share_factor(name):.4f}")

    print("\n== per-tenant SLO percentiles (sdiag's serving section) ==")
    print(tracer.slo.format_report())

    print("\n== continuous batching: a mixed-length burst ==")
    # Classic admission prefills a whole prompt in one shot, so the
    # 360-token batch prompt below would head-of-line block the three
    # interactive shorts submitted right behind it.  A token budget
    # (``max_batch_tokens``) packs prefill CHUNKS into the leftover of
    # every decode step instead: the shorts promote after one chunk and
    # stream tokens while the long prompt is still mid-prefill — short
    # TTFT stays flat no matter how long the longest resident prompt is.
    budgeted = DecodeEngine(cfg, params, num_slots=4, cache_len=512,
                            metrics=metrics, admission=admission,
                            decode_chunk=4, kv_page_size=16,
                            max_batch_tokens=64)
    long_req = Request(rid=900, prompt=rng.integers(
        0, cfg.vocab_size, 360).astype(np.int32),
        max_new_tokens=4, tenant="research", qos="scavenger")
    shorts = [Request(rid=901 + i, prompt=rng.integers(
        0, cfg.vocab_size, 8 + 2 * i).astype(np.int32),
        max_new_tokens=12, tenant="prod") for i in range(3)]
    budgeted.submit(long_req)                  # the would-be blocker...
    for r in shorts:
        budgeted.submit(r)                     # ...and the burst behind it
    steps = 0
    while not all(r.output for r in shorts):
        budgeted.step()
        steps += 1
    part = next(p for p in budgeted._partials if p.req is long_req)
    print(f"after {steps} step(s): every short is decoding "
          f"({[len(r.output) for r in shorts]} tokens) while the long "
          f"prompt is {part.pos_filled}/{len(long_req.prompt)} prefilled")
    budgeted.run_to_completion()
    assert long_req.done and all(r.done for r in shorts)

    print("\n== serve-step utilization (sdiag's budgeted section) ==")
    from repro.cluster import commands
    print(commands.sdiag(engine=budgeted))

    print("\n== speculative decoding: draft-and-verify ==")
    # Prompt-lookup speculation: the engine drafts k tokens per lane
    # from the request's own repeats (and a cross-request index fed at
    # finish), then verifies them all in ONE target dispatch — greedy
    # output is bit-identical, wrong drafts only cost speed.  The
    # repetitive prompt below is the friendly regime: most rounds
    # accept several drafts, so tokens-per-dispatch climbs above 1.
    spec = DecodeEngine(cfg, params, num_slots=2, cache_len=128,
                        metrics=metrics, admission=admission,
                        decode_chunk=4, kv_page_size=16, speculate=4)
    phrase = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    looped = Request(rid=950, prompt=np.concatenate([phrase] * 4),
                     max_new_tokens=24, tenant="prod")
    spec.submit(looped)
    spec.run_to_completion()
    plain = DecodeEngine(cfg, params, num_slots=2, cache_len=128,
                         decode_chunk=4, kv_page_size=16)
    check = Request(rid=951, prompt=looped.prompt.copy(),
                    max_new_tokens=24, tenant="prod")
    plain.submit(check)
    plain.run_to_completion()
    assert looped.output == check.output, "speculation changed output"
    st = spec.spec_stats
    print(f"{len(looped.output)} tokens, {st['emitted']} of them from "
          f"{st['rounds']} verify rounds "
          f"({st['emitted'] / max(st['rounds'], 1):.1f} tokens/round), "
          f"accepted {st['accepted']}/{st['proposed']} drafts — output "
          f"bit-identical to plain decoding")
    print(commands.sdiag(engine=spec))


if __name__ == "__main__":
    main()
