"""Validate a Chrome trace-event JSON artifact (CI gate for --trace).

Checks the properties Perfetto/chrome://tracing rely on: the file parses,
``traceEvents`` is non-empty, every event carries the required keys for
its phase, timestamps are monotonically ordered, and every ``parent_sid``
refers to a span that exists.  Usage::

    python scripts/validate_trace.py serve_trace.json
"""
from __future__ import annotations

import json
import sys


def validate(path: str) -> list[str]:
    errors: list[str] = []
    with open(path) as f:
        data = json.load(f)
    events = data.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: traceEvents missing or empty"]
    spans = [e for e in events if e.get("ph") == "X"]
    if not spans:
        errors.append(f"{path}: no complete ('X') span events")
    last_ts = None
    sids = {e["args"]["sid"] for e in spans if "sid" in e.get("args", {})}
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph == "M":
            continue
        for key in ("name", "ts", "pid", "tid"):
            if key not in e:
                errors.append(f"event {i}: missing {key!r}")
        if ph == "X" and "dur" not in e:
            errors.append(f"event {i} ({e.get('name')}): 'X' without dur")
        ts = e.get("ts")
        if ts is not None:
            if last_ts is not None and ts < last_ts:
                errors.append(f"event {i} ({e.get('name')}): ts {ts} < "
                              f"previous {last_ts} (not sorted)")
            last_ts = ts
        parent = e.get("args", {}).get("parent_sid")
        if parent is not None and parent not in sids:
            errors.append(f"event {i} ({e.get('name')}): parent_sid "
                          f"{parent} not in trace")
    return errors


def main(argv=None) -> int:
    paths = (argv if argv is not None else sys.argv[1:]) or []
    if not paths:
        print("usage: validate_trace.py TRACE_JSON [...]", file=sys.stderr)
        return 2
    rc = 0
    for path in paths:
        errs = validate(path)
        if errs:
            rc = 1
            for e in errs:
                print(f"[validate_trace] {e}", file=sys.stderr)
        else:
            n = len(json.load(open(path))["traceEvents"])
            print(f"[validate_trace] {path}: ok ({n} events)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
