"""Dissect per-device flops of a probe program: group dot ops by shape.

Parses the optimized HLO, indexes every instruction's output shape, then
computes flops per dot from operand/contracting dims.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import dataclasses
import math
import re
import sys
from collections import defaultdict

from repro.configs import INPUT_SHAPES, default_run_config, get_config
from repro.launch import dryrun as D
from repro.launch.mesh import make_production_mesh

arch = sys.argv[1] if len(sys.argv) > 1 else "starcoder2-3b"
shape_name = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
groups = int(sys.argv[3]) if len(sys.argv) > 3 else 1
micro = int(sys.argv[4]) if len(sys.argv) > 4 else 2

mesh = make_production_mesh()
shape = INPUT_SHAPES[shape_name]
cfg0 = get_config(arch)
run = default_run_config(cfg0, shape, batch_divisor=16)

from repro.models.spec import group_period
P = group_period(cfg0)
cfg = dataclasses.replace(cfg0, num_layers=P * groups)
run = dataclasses.replace(run, unroll=True, microbatches=micro)
print(f"{arch} {shape_name} groups={groups} micro={micro} "
      f"layers={cfg.num_layers} strategy={run.strategy}")

low = D.lower_step(cfg, run, shape, mesh)
comp = low.compile()
cost = comp.cost_analysis()
print("cost_analysis flops/device:", f"{cost.get('flops', 0):.4g}")
print("cost_analysis bytes/device:", f"{cost.get('bytes accessed', 0):.4g}")

txt = comp.as_text()

def_re = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(?(\w+)\[([\d,]*)\]")
shape_of: dict[str, list[int]] = {}
for line in txt.splitlines():
    m = def_re.match(line)
    if m:
        shape_of[m.group(1)] = [int(x) for x in m.group(3).split(",") if x]

dot_line_re = re.compile(r"=\s*\w+\[([\d,]*)\][^=]*?\sdot\(")
oper_re = re.compile(r"dot\(\s*(?:\w+\[[\d,]*\]\{[\d,]*\}\s+)?%?([\w.\-]+),\s*(?:\w+\[[\d,]*\]\{[\d,]*\}\s+)?%?([\w.\-]+)\s*\)")
lc_re = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

flops_by_sig = defaultdict(float)
count_by_sig = defaultdict(int)
missed = 0
for line in txt.splitlines():
    if " dot(" not in line:
        continue
    m = dot_line_re.search(line)
    if not m:
        continue
    out_dims = [int(x) for x in m.group(1).split(",") if x]
    om = oper_re.search(line)
    lc = lc_re.search(line)
    if not om or not lc:
        missed += 1
        continue
    lhs_name = om.group(1)
    lhs_dims = shape_of.get(lhs_name)
    if lhs_dims is None:
        missed += 1
        continue
    k = 1
    for d in (int(x) for x in lc.group(1).split(",") if x):
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    fl = 2 * k * math.prod(out_dims) if out_dims else 0
    sig = f"lhs{lhs_dims} k={k} -> out{out_dims}"
    flops_by_sig[sig] += fl
    count_by_sig[sig] += 1

tot = sum(flops_by_sig.values())
print(f"sum of dot flops: {tot:.4g}  (missed {missed} dot lines)")
for sig, fl in sorted(flops_by_sig.items(), key=lambda kv: -kv[1])[:25]:
    print(f"  {fl:11.4g} ({fl/max(tot,1)*100:5.1f}%) n={count_by_sig[sig]:4d}  {sig}")
