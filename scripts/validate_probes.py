"""Validate dryrun probe-fit extrapolation against full unroll ground truth.

Uses a small mesh (16 devices) and a small config so the FULL program can be
unrolled and measured directly; compares with the probe fit at the same
(m, G).  Also prints memory_analysis of the scanned production program to
audit the temp-bytes accounting.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

import dataclasses
import jax

from repro.configs import get_config, INPUT_SHAPES, default_run_config
from repro.launch import dryrun as D

mesh = jax.make_mesh((4, 4), ("data", "model"))

cfg = get_config("starcoder2-3b")
# shrink so full unroll is tractable: 6 layers, small vocab/batch/seq
cfg = dataclasses.replace(cfg, num_layers=6, d_model=512, num_heads=8,
                          num_kv_heads=2, head_dim=64, d_ff=2048,
                          vocab_size=4096)
shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=512,
                            global_batch=32)
run = default_run_config(cfg, shape, batch_divisor=4)
run = dataclasses.replace(run, microbatches=4)
print("run:", run)

# ground truth: fully unrolled full program
full = D._probe_metrics(cfg, dataclasses.replace(run, unroll=True), shape, mesh)
print("FULL-unroll :", {k: f"{v:.4g}" for k, v in full.items()})

# probe fit
fit = D.probe_costs(cfg, run, shape, mesh)
print("PROBE-fit   :", {k: f"{v:.4g}" for k, v in fit.items()})

for k in ("flops", "hbm_bytes", "link_bytes"):
    rel = (fit[k] - full[k]) / max(full[k], 1)
    print(f"{k:12s} full={full[k]:.4g} fit={fit[k]:.4g} rel_err={rel:+.3%}")

# memory of the scanned production program
low = D.lower_step(cfg, run, shape, mesh)
comp = low.compile()
mem = comp.memory_analysis()
print("scan prod: arg=%.3g out=%.3g temp=%.3g" % (
    mem.argument_size_in_bytes, mem.output_size_in_bytes,
    mem.temp_size_in_bytes))
cost = comp.cost_analysis()
print("scan prod flops(once)=%.4g bytes=%.4g" % (
    cost.get("flops", 0), cost.get("bytes accessed", 0)))
